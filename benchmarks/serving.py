"""Serving benchmark: continuous batching vs lockstep, across compression
policies and batch sizes.

The workload has mixed response lengths (per-request new-token caps drawn
from a fixed spread), which is exactly where lockstep decoding bleeds: every
batch runs to the global ``max_new`` while finished rows feed padding, so
its useful-token fraction is mean(cap)/max_new.  Continuous batching
recycles a finished row's fixed-size slot block into the next queued prompt
and keeps the decode batch full.  Both paths emit token-identical outputs
per request (same per-request key chains), so the comparison is pure
scheduling.

  PYTHONPATH=src python -m benchmarks.serving --smoke
  PYTHONPATH=src python -m benchmarks.serving --smoke --policies rkv,none

Row format matches benchmarks.run: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List, Optional

import jax
import numpy as np

OUT = "reports/benchmarks"


def _make_requests(n: int, prompt_len: int, max_new: int, seed: int):
    """n burst-arrival requests with the serve CLI's long-tailed spread of
    per-request response caps (most responses short, a few near ``max_new``
    — the shape real serving traffic has, and the regime where lockstep
    decoding pays ``max_new`` steps for every row)."""
    from repro.launch.serve import make_workload

    reqs, _, _ = make_workload(n, prompt_len, max_new, rate=0.0,
                               resp_dist="mixed", seed=seed)
    return reqs


def _bench_one(arch: str, policy: str, batch: int, n_requests: int,
               prompt_len: int, max_new: int, decode_chunk: int, seed: int):
    """Returns a dict of measured numbers for one (policy, batch) cell."""
    from dataclasses import replace

    from repro.configs import SparseRLConfig, get_config
    from repro.data import TOKENIZER
    from repro.models import get_model
    from repro.rollout import ContinuousEngine, LockstepServer

    cfg = get_config(arch).smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(seed))
    scfg = SparseRLConfig(compression=policy)
    if policy != "none":
        scfg = replace(scfg, kv_budget=16, kv_buffer=8, obs_window=4,
                       num_sinks=2)
    reqs = _make_requests(n_requests, prompt_len, max_new, seed)

    srv = LockstepServer(params, cfg, m, scfg, batch_size=batch,
                         prompt_len=prompt_len, max_new_tokens=max_new,
                         eos_id=TOKENIZER.eos_id, seed=seed)
    eng = ContinuousEngine(params, cfg, m, scfg, batch_size=batch,
                           prompt_len=prompt_len, max_new_tokens=max_new,
                           eos_id=TOKENIZER.eos_id, decode_chunk=decode_chunk,
                           seed=seed)
    # warm both (compile), then interleave best-of-N so machine-load drift
    # hits both schedulers alike; best-of filters the noise floor.  The
    # engine clock/stats reset each repeat so reported counters are per-run.
    lock, cont = srv.run(reqs), eng.run(reqs)
    t_lock = t_cont = float("inf")
    for _ in range(4):
        t0 = time.perf_counter()
        lock = srv.run(reqs)
        t_lock = min(t_lock, time.perf_counter() - t0)
        eng.reset_clock()
        t0 = time.perf_counter()
        cont = eng.run(reqs)
        t_cont = min(t_cont, time.perf_counter() - t0)

    toks_lock = sum(len(c.tokens) for c in lock)
    toks_cont = sum(len(c.tokens) for c in cont)
    identical = all(np.array_equal(a.tokens, b.tokens)
                    for a, b in zip(cont, lock))
    return dict(policy=policy, batch=batch, n_requests=n_requests,
                max_new=max_new, tokens=toks_cont,
                lockstep_s=t_lock, continuous_s=t_cont,
                lockstep_tps=toks_lock / t_lock,
                continuous_tps=toks_cont / t_cont,
                speedup=t_lock / t_cont, identical=identical,
                decode_steps=int(eng.stats["decode_steps"]),
                wasted_row_steps=int(eng.stats["wasted_row_steps"]))


def serving_bench(fast: bool = False, *, arch: str = "qwen2.5-14b",
                  policies=("rkv", "none"), batches: Optional[tuple] = None,
                  seed: int = 0) -> List[str]:
    if batches is None:
        batches = (4,) if fast else (4, 8)
    n_requests = 12 if fast else 32
    max_new = 64 if fast else 96
    prompt_len = 16
    decode_chunk = 8
    rows, out = [], []
    for policy in policies:
        for batch in batches:
            r = _bench_one(arch, policy, batch, n_requests, prompt_len,
                           max_new, decode_chunk, seed)
            rows.append(r)
            base = f"serving/{policy}/b{batch}"
            out.append(f"{base}/lockstep,{r['lockstep_s']*1e6:.0f},"
                       f"toks_per_s={r['lockstep_tps']:.1f}")
            out.append(f"{base}/continuous,{r['continuous_s']*1e6:.0f},"
                       f"toks_per_s={r['continuous_tps']:.1f};"
                       f"speedup={r['speedup']:.2f};"
                       f"identical={r['identical']}")
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "serving.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast workload (CPU CI)")
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--policies", default="rkv,none",
                    help="comma-separated compression policies to compare")
    ap.add_argument("--batches", default=None,
                    help="comma-separated decode batch sizes")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    batches = (tuple(int(b) for b in args.batches.split(","))
               if args.batches else None)
    print("name,us_per_call,derived")
    rows = serving_bench(fast=args.smoke, arch=args.arch,
                         policies=tuple(args.policies.split(",")),
                         batches=batches, seed=args.seed)
    for r in rows:
        print(r, flush=True)
    # the acceptance bar: continuous must not serve slower than lockstep
    with open(os.path.join(OUT, "serving.json")) as f:
        results = json.load(f)
    worst = min(r["speedup"] for r in results)
    ok = worst >= 1.0 and all(r["identical"] for r in results)
    print(f"continuous>=lockstep: {worst:.2f}x worst-case speedup "
          f"({'PASS' if ok else 'FAIL'})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
