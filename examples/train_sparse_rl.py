"""End-to-end driver: train a small model with GRPO + Sparse-RL for a few
hundred steps on the synthetic verifiable-math task, with checkpoint/restart.

Compares three conditions if --compare is given (dense / naive sparse /
Sparse-RL), reproducing the paper's stability story at laptop scale.

  PYTHONPATH=src python examples/train_sparse_rl.py --steps 200
  PYTHONPATH=src python examples/train_sparse_rl.py --steps 60 --compare
"""
import argparse
import json
import shutil

import numpy as np

from repro.configs import SparseRLConfig, TrainConfig, get_config
from repro.runtime import Trainer, TrainerOptions


def run(condition: str, steps: int, seed: int, ckpt: str):
    cfg = get_config("qwen2.5-14b").smoke()
    scfg = SparseRLConfig(kv_budget=16, kv_buffer=4, obs_window=2,
                          num_sinks=1, group_size=8, max_new_tokens=16,
                          learning_rate=5e-4, kl_coef=0.0)
    if condition == "dense":
        scfg = scfg.dense()
    elif condition == "naive":
        scfg = scfg.naive()
    tcfg = TrainConfig(update_batch=32, total_steps=steps, warmup_steps=5,
                       checkpoint_every=50, checkpoint_dir=ckpt, seed=seed)
    opts = TrainerOptions(num_prompts=8, prompt_len=16, max_new_tokens=16,
                          level="easy", group_slack=0)
    tr = Trainer(cfg, scfg, tcfg, opts)
    todo = steps - tr.step
    if tr.step:
        print(f"[{condition}] resumed from checkpoint at step {tr.step}")
    hist = tr.train(todo, log_every=20)
    tr.save_checkpoint()
    return hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare", action="store_true")
    ap.add_argument("--fresh", action="store_true", help="ignore checkpoints")
    args = ap.parse_args()

    conds = ["sparse_rl"] if not args.compare else ["dense", "naive", "sparse_rl"]
    results = {}
    for cond in conds:
        ckpt = f"/tmp/srl_example_{cond}_{args.seed}"
        if args.fresh:
            shutil.rmtree(ckpt, ignore_errors=True)
        hist = run(cond, args.steps, args.seed, ckpt)
        tail = hist[-max(1, len(hist) // 4):]
        results[cond] = dict(
            reward_final=float(np.mean([h["reward"] for h in tail])),
            reward_first=hist[0]["reward"],
            grad_p95=float(np.percentile([h["grad_norm"] for h in hist], 95)),
            rejection=float(np.mean([h["rejection_rate"] for h in tail])),
        )
        print(f"[{cond}] final reward {results[cond]['reward_final']:.3f} "
              f"(start {results[cond]['reward_first']:.3f}), "
              f"grad p95 {results[cond]['grad_p95']:.2f}")
    print(json.dumps(results, indent=1))


if __name__ == "__main__":
    main()
