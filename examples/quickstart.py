"""Quickstart: the Sparse-RL loop in ~60 lines of public API.

Rolls out from the SPARSE sampler (budget KV cache), verifies, rescores
dense, applies the Eq. 7 corrected update — and prints the three-policy
diagnostics (xi, rejection, mismatch KL) that make the paper tick.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SparseRLConfig, get_config
from repro.core import group_advantages, sparse_rl_loss
from repro.data import TOKENIZER, encode_prompts, make_problems
from repro.models import get_model
from repro.optim import adamw
from repro.rewards import binary_rewards
from repro.rollout import generate, rescore

# 1. a small qwen-family model (same architecture family as the paper)
cfg = get_config("qwen2.5-14b").smoke()
m = get_model(cfg)
params = m.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw.init(params)

# 2. sparse rollout config: budget cache + the paper's two corrections
scfg = SparseRLConfig(kv_budget=16, kv_buffer=4, obs_window=2, num_sinks=1,
                      compression="rkv", group_size=4, max_new_tokens=16,
                      learning_rate=3e-4, rejection_eps=1e-4)

# 3. prompts -> G sparse rollouts each
problems = make_problems(8, seed=0, level="easy")
ids, mask, answers = encode_prompts(problems, 16)
G = scfg.group_size
batch = {"tokens": jnp.asarray(np.repeat(ids, G, 0)),
         "valid_mask": jnp.asarray(np.repeat(mask, G, 0))}
ro = generate(params, cfg, m, batch, scfg, jax.random.PRNGKey(1),
              max_new_tokens=scfg.max_new_tokens, eos_id=TOKENIZER.eos_id)
print(f"rolled out {ro.resp_tokens.shape[0]} responses, "
      f"mean len {float(ro.lengths.mean()):.1f}, "
      f"cache slots/layer: {scfg.cache_slots} (vs {ids.shape[1] + scfg.max_new_tokens} dense)")

# 4. binary rewards + group advantages (GRPO)
rewards = binary_rewards(np.asarray(ro.resp_tokens), list(np.repeat(answers, G)))
adv = group_advantages(jnp.asarray(rewards.reshape(-1, G))).reshape(-1)
print(f"reward: {rewards.mean():.3f}")

# 5. dense re-scoring with the SAME weights -> pi_old (the xi numerator)
logp_old = rescore(params, cfg, m, ro)

# 6. the Sparse-RL update (Eq. 7)
def loss_fn(p):
    logp_theta = rescore(p, cfg, m, ro)
    out = sparse_rl_loss(logp_theta, logp_old, ro.logp_sparse, adv,
                         ro.resp_mask, scfg)
    return out.loss, out.metrics

(loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
params, opt, om = adamw.update(params, grads, opt, lr=scfg.learning_rate,
                               grad_clip=1.0)
print(f"loss={float(loss):.4f} grad_norm={float(om['grad_norm']):.3f}")
print(f"mismatch_kl={float(metrics['mismatch_kl']):.4f} "
      f"mean_xi={float(metrics['mean_xi']):.3f} "
      f"rejection_rate={float(metrics['rejection_rate']):.3f} "
      f"clip_ratio={float(metrics['clip_ratio']):.5f}")
print("OK — one full Sparse-RL step.")
