"""Paper Fig. 4 at laptop scale: sweep the KV budget and watch reward /
mismatch-KL / rejection respond.

  PYTHONPATH=src python examples/budget_ablation.py --steps 30
"""
import argparse
import json
import shutil

import numpy as np

from repro.configs import SparseRLConfig, TrainConfig, get_config
from repro.runtime import Trainer, TrainerOptions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--budgets", default="4,8,16,32")
    args = ap.parse_args()

    cfg = get_config("qwen2.5-14b").smoke()
    rows = {}
    for budget in [int(b) for b in args.budgets.split(",")] + ["dense"]:
        if budget == "dense":
            scfg = SparseRLConfig(compression="none", group_size=8,
                                  max_new_tokens=16, learning_rate=5e-4)
        else:
            scfg = SparseRLConfig(kv_budget=budget, kv_buffer=4, obs_window=2,
                                  num_sinks=1, group_size=8, max_new_tokens=16,
                                  learning_rate=5e-4)
        d = f"/tmp/srl_ablate_{budget}"
        shutil.rmtree(d, ignore_errors=True)
        tcfg = TrainConfig(update_batch=32, total_steps=args.steps,
                           warmup_steps=2, checkpoint_every=0, checkpoint_dir=d)
        tr = Trainer(cfg, scfg, tcfg,
                     TrainerOptions(num_prompts=8, prompt_len=16,
                                    max_new_tokens=16))
        hist = tr.train(args.steps, log_every=0)
        tail = hist[-max(1, len(hist) // 4):]
        rows[str(budget)] = dict(
            reward=float(np.mean([h["reward"] for h in tail])),
            mismatch_kl=float(np.mean([abs(h["mismatch_kl"]) for h in tail])),
            rejection=float(np.mean([h["rejection_rate"] for h in tail])))
        print(f"budget={budget}: {rows[str(budget)]}")
    print(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
