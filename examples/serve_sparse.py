"""Serve a model with batched requests under a sparse KV cache — the
deployment half of the paper (§5.4 sparsity-aware training).

Points at a checkpoint from train_sparse_rl.py if available; otherwise
serves a fresh init.  Reports tokens/s and per-sequence cache memory vs the
dense equivalent.

  PYTHONPATH=src python examples/serve_sparse.py --batch 16 --max-new 32
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore
from repro.configs import SparseRLConfig, get_config
from repro.data import TOKENIZER, encode_prompts, make_problems
from repro.models import get_model
from repro.rewards import binary_rewards, decode_responses
from repro.rollout import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--ckpt", default="/tmp/srl_example_sparse_rl_0")
    args = ap.parse_args()

    cfg = get_config("qwen2.5-14b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    if latest_step(args.ckpt) is not None:
        got, step, _ = restore(args.ckpt, {"params": params})
        params = got["params"]
        print(f"serving checkpoint step {step} from {args.ckpt}")
    else:
        print("no checkpoint found — serving fresh init")

    scfg = SparseRLConfig(kv_budget=args.budget, kv_buffer=4, obs_window=2,
                          num_sinks=1, compression="rkv")
    problems = make_problems(args.batch, 123, "easy")
    ids, mask, answers = encode_prompts(problems, 24)
    batch = {"tokens": jnp.asarray(ids), "valid_mask": jnp.asarray(mask)}

    gen = jax.jit(lambda p, b, r: generate(p, cfg, m, b, scfg, r,
                                           max_new_tokens=args.max_new,
                                           eos_id=TOKENIZER.eos_id))
    ro = gen(params, batch, jax.random.PRNGKey(1))          # compile
    jax.block_until_ready(ro.resp_tokens)
    t0 = time.time()
    ro = gen(params, batch, jax.random.PRNGKey(2))
    jax.block_until_ready(ro.resp_tokens)
    dt = time.time() - t0

    toks = int(np.asarray(ro.lengths).sum())
    acc = binary_rewards(np.asarray(ro.resp_tokens), answers).mean()
    dense_slots = ids.shape[1] + args.max_new
    per_tok = cfg.num_layers * cfg.num_kv_heads * cfg.head_dim * 2 * 4
    print(f"batch={args.batch} tokens={toks} {toks/dt:.0f} tok/s  acc={acc:.2f}")
    print(f"cache/seq: sparse {scfg.cache_slots * per_tok / 1e3:.1f} KB "
          f"vs dense {dense_slots * per_tok / 1e3:.1f} KB "
          f"({1 - scfg.cache_slots / dense_slots:.0%} saved; grows with ctx)")
    for i, r in enumerate(decode_responses(np.asarray(ro.resp_tokens))[:4]):
        print(f"  [{i}] {problems[i].prompt!r} -> {r!r} (gold {problems[i].answer})")


if __name__ == "__main__":
    main()
