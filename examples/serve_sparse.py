"""Serve a model with continuous batching under a sparse KV cache — the
deployment half of the paper (§5.4 sparsity-aware training).

Requests stream through the continuous-batching engine: a fixed decode batch
whose rows are recycled as requests finish (each row owns a constant
``B_budget + B_buffer`` slot block — the fixed footprint that makes slot
recycling a static-shape op).  Points at a checkpoint from
train_sparse_rl.py if available; otherwise serves a fresh init.  Reports
tokens/s for continuous vs lockstep scheduling and per-sequence cache memory
vs the dense equivalent.

  PYTHONPATH=src python examples/serve_sparse.py --num-requests 16 --max-new 32
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint import latest_step, restore
from repro.configs import SparseRLConfig, get_config
from repro.data import TOKENIZER
from repro.launch.serve import make_workload
from repro.models import get_model
from repro.rewards import binary_rewards, decode_responses
from repro.rollout import ContinuousEngine, LockstepServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--ckpt", default="/tmp/srl_example_sparse_rl_0")
    args = ap.parse_args()

    cfg = get_config("qwen2.5-14b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    if latest_step(args.ckpt) is not None:
        got, step, _ = restore(args.ckpt, {"params": params})
        params = got["params"]
        print(f"serving checkpoint step {step} from {args.ckpt}")
    else:
        print("no checkpoint found — serving fresh init")

    scfg = SparseRLConfig(kv_budget=args.budget, kv_buffer=4, obs_window=2,
                          num_sinks=1, compression="rkv")
    prompt_len = 24
    # mixed response caps: the workload shape where slot recycling pays
    reqs, problems, answers = make_workload(
        args.num_requests, prompt_len, args.max_new, rate=0.0,
        resp_dist="mixed", seed=123)

    eng = ContinuousEngine(params, cfg, m, scfg, batch_size=args.batch,
                           prompt_len=prompt_len, max_new_tokens=args.max_new,
                           eos_id=TOKENIZER.eos_id, seed=0)
    eng.run(reqs)                       # compile
    eng.reset_clock()
    t0 = time.perf_counter()
    completions = eng.run(reqs)
    dt = time.perf_counter() - t0

    srv = LockstepServer(params, cfg, m, scfg, batch_size=args.batch,
                         prompt_len=prompt_len, max_new_tokens=args.max_new,
                         eos_id=TOKENIZER.eos_id, seed=0)
    srv.run(reqs)                       # compile
    t0 = time.perf_counter()
    lock = srv.run(reqs)
    dt_lock = time.perf_counter() - t0

    toks = sum(len(c.tokens) for c in completions)
    resp = np.zeros((len(completions), args.max_new), np.int32)
    for i, c in enumerate(completions):
        resp[i, :len(c.tokens)] = c.tokens
    acc = binary_rewards(resp, answers).mean()
    dense_slots = prompt_len + args.max_new
    per_tok = cfg.num_layers * cfg.num_kv_heads * cfg.head_dim * 2 * 4
    same = all(np.array_equal(a.tokens, b.tokens)
               for a, b in zip(completions, lock))
    print(f"{args.num_requests} requests, {toks} tokens  "
          f"continuous {toks/dt:.0f} tok/s vs lockstep {toks/dt_lock:.0f} "
          f"tok/s ({dt_lock/dt:.2f}x)  acc={acc:.2f}  "
          f"token-identical={same}")
    print(f"cache/seq: sparse {scfg.cache_slots * per_tok / 1e3:.1f} KB "
          f"vs dense {dense_slots * per_tok / 1e3:.1f} KB "
          f"({1 - scfg.cache_slots / dense_slots:.0%} saved; grows with ctx)")
    for i, r in enumerate(decode_responses(resp[:4])):
        print(f"  [{i}] {problems[i].prompt!r} -> {r!r} (gold {answers[i]})")


if __name__ == "__main__":
    main()
