"""Autotune CLI: sweep the Pallas kernels' tunable configs and persist
winners (`kernels/autotune.py` does the work; PERFORMANCE.md documents the
model; DESIGN.md §Kernel autotuning the design).

  PYTHONPATH=src python -m tools.autotune --dry-run --all
      print every sweep cell's candidate grid and schema-validate all
      checked-in kernels/tuned/*.json files — no timing, CI-safe.
  PYTHONPATH=src python -m tools.autotune --all [--smoke]
      sweep every kernel on this device; report rows land in
      reports/autotune.json, winners merge into
      kernels/tuned/<device_kind>.json.  Off-TPU the device kind is
      ``interpret`` and persisting needs --force: interpret-mode timings
      measure the Python interpreter, not a device, so they must never be
      mistaken for tuned configs (CI pins the defaults instead).
  PYTHONPATH=src python -m tools.autotune --kernel paged_decode
      sweep a single kernel.

Winners are only ever persisted after passing the kernels/ref.py oracle
check and the launch/roofline.py sanity bound (a measured time below the
analytic lower bound is a measurement bug, not a win).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from repro.kernels import autotune as at

# canonical sweep cells per kernel: the production geometry (hd128) plus
# the smoke-model geometry CI runs (hd16); paged cells carry the page size
DEFAULT_CELLS = {
    "paged_decode": [(16, 8), (128, 8), (128, 32)],
    "flash_attention": [(16, 0), (128, 0)],
    "budget_attention": [(16, 0), (128, 0)],
    "flash_decode": [(16, 0), (128, 0)],
}


def keys_for(kernels):
    out = []
    for kernel in kernels:
        for hd, ps in DEFAULT_CELLS[kernel]:
            out.append(at.tune_key(kernel, head_dim=hd, page_size=ps))
    return out


def validate_all_tuned(directory: str) -> list:
    """Round-trip schema validation of every checked-in tuned file."""
    checked = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        kind = os.path.splitext(os.path.basename(path))[0]
        with open(path) as f:
            entries = at.validate_tuned(json.load(f), kind=kind)
        checked.append(dict(file=os.path.relpath(path), kind=kind,
                            entries=len(entries)))
        print(f"  tuned schema ok: {path} ({len(entries)} entries)")
    return checked


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--all", action="store_true",
                    help="sweep every kernel")
    ap.add_argument("--kernel", action="append", choices=at.KERNELS,
                    default=[], help="sweep one kernel (repeatable)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print candidate grids + validate tuned JSON "
                         "schemas, no timing")
    ap.add_argument("--smoke", action="store_true",
                    help="small synthetic workloads (fast, CI-sized)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="timing repeats per candidate (median taken)")
    ap.add_argument("--out", default=os.path.join("reports", "autotune.json"))
    ap.add_argument("--force", action="store_true",
                    help="allow persisting winners for the 'interpret' "
                         "device kind (normally refused: interpret timings "
                         "measure the Python interpreter, not a device)")
    args = ap.parse_args(argv)

    kernels = tuple(dict.fromkeys(args.kernel)) or (at.KERNELS if args.all
                                                    else ())
    if not kernels:
        ap.error("pick --all or at least one --kernel")
    kind = at.device_kind()
    keys = keys_for(kernels)
    report = dict(schema=at.SCHEMA_VERSION, device_kind=kind,
                  mode="dry_run" if args.dry_run else "sweep", rows=[])

    if args.dry_run:
        print(f"device_kind={kind} (dry run: no timing)")
        for key in keys:
            cands = at.candidate_space(key)
            dflt = at.default_config(key)
            print(f"{key.s}: {len(cands)} candidates "
                  f"(default {dflt}): {cands}")
            report["rows"].append(dict(
                kernel=key.kernel, key=key.s, device_kind=kind,
                candidates=cands, default=dflt,
                vmem_bytes=[at.vmem_bytes(key, c) for c in cands]))
        report["tuned_files"] = validate_all_tuned(at.tuned_dir())
    else:
        scale = "smoke" if args.smoke else "full"
        results = []
        for key in keys:
            print(f"sweeping {key.s} on {kind} ...")
            r = at.sweep(key, kind=kind,
                         workload=at.default_workload(key, scale),
                         repeats=args.repeats)
            for row in r.report_rows():
                flag = ("WINNER" if row["winner"] else
                        "ok" if row["accepted"] else
                        f"REJECTED ({row['reject_reason']})")
                us = f"{row['us']:.1f}us" if row["us"] else "-"
                print(f"  {row['config']}: {us}  {flag}")
            report["rows"].extend(r.report_rows())
            results.append(r)
        if kind == "interpret" and not args.force:
            print("not persisting: device_kind is 'interpret' "
                  "(pass --force to override)")
        else:
            path = at.persist(results, kind=kind)
            print(f"tuned configs -> {path}")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
        f.write("\n")
    print(f"report -> {args.out} ({len(report['rows'])} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
