#!/usr/bin/env python3
"""Benchmark regression gate for CI (stdlib only, no jax import).

Compares freshly-run smoke sections of the BENCH_*.json files against the
committed baselines and fails (exit 1) when:

1. any fresh row reports ``identical: false`` — the schedulers must stay
   token-identical to their lockstep oracles (a wrong-but-fast engine is a
   bug, not a speedup); likewise ``reward_nondegrading: false`` — the
   async actor-learner pipeline's smoke run must not lose reward over its
   horizon (a fast-but-destabilizing pipeline is a bug, not a speedup);
2. a ``rollout_phase(_smoke)`` row has ``speedup < 1.0`` — the ISSUE-3
   acceptance bound: the continuous-paged training rollout phase may never
   be slower than the lockstep phase on the mixed-length group workload
   (the ``rollout_async*`` sections are exempt from this floor: overlap
   gains are hardware-dependent, so their steps/s only tolerance-bands);
3. a fresh row's ``speedup`` regresses below ``committed * (1 - tolerance)``
   — rows are matched by their identity fields (policy/batch/group_size/...),
   so reordering sections does not confuse the gate.  A section absent
   from the committed baseline (e.g. async rows against a pre-async
   baseline) skips only this banded check; (1) and (2) still gate.
4. a quantized-pool row (``kv_quant`` other than "none") reports
   ``capacity_ratio < 1.8`` — the ISSUE-6 acceptance bound: int8/fp8 pools
   must actually buy >= 1.8x effective KV capacity per HBM byte at equal
   block count.  Quant rows carry no ``identical`` bound (the quantized
   cache is a *corrected sampler policy* — tokens legitimately diverge and
   the xi/rejection machinery absorbs the mismatch; DESIGN.md §Quantized
   paged pool) but their ``reward_nondegrading`` is hard-gated like the
   async rows, and their speedup is tolerance-banded, not floored (CPU
   dequant can cost more than the bandwidth it saves).  Baselines
   committed before the quant sections existed still gate: the hard
   bounds apply to every fresh row, pairing just starts at the next
   baseline regeneration.

The tolerance band (default 0.35) absorbs shared-CI-runner noise; the hard
bounds (1) and (2) have no band.  A section missing from the committed
baseline is skipped for (3) — first landing of a new bench — but its hard
bounds still apply.

Rows additionally pair by **config provenance**: every bench row records
``config_source`` ("tuned" when any kernel resolved an autotuned config,
"default" otherwise — `kernels.ops.config_provenance`; PERFORMANCE.md), and
the banded comparison (3) only matches fresh rows against committed rows of
the *same* provenance.  A tuned-row regression must not hide behind a slower
default baseline, and a default row must not be judged against a tuned
baseline's faster numbers.  Rows with no ``config_source`` field (baselines
committed before autotuning existed) count as "default".  Usage (the ci.yml
bench job):

  cp BENCH_serving.json BENCH_rollout.json /tmp/bench_committed/
  python -m benchmarks.serving --smoke && python -m benchmarks.rollout --smoke
  python tools/bench_gate.py --committed /tmp/bench_committed --fresh .
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

# section -> fields identifying a row within it (used to pair fresh rows
# with committed rows for the regression comparison)
GATED_SECTIONS = {
    "BENCH_serving.json": {
        "continuous_vs_lockstep_smoke": ("policy", "batch", "plen_dist"),
        "paged_prefix_smoke": ("group_size", "n_prompts"),
        # quantized paged pool vs the fp paged pool (one row per kv_quant);
        # capacity_ratio >= 1.8 hard-gates every quantized row
        "paged_quant_smoke": ("kv_quant", "group_size"),
    },
    "BENCH_rollout.json": {
        "rollout_phase_smoke": ("policy", "group_size", "n_prompts",
                                "plen_dist"),
        # CI only re-runs the smoke benches, so for the full-scale section
        # fresh == committed and the tolerance check is a no-op — but the
        # hard bounds below still vet the committed numbers on every push
        "rollout_phase": ("policy", "group_size", "n_prompts", "plen_dist"),
        # async actor-learner pipeline cells (steps/s vs the sync trainer;
        # lag-0 identity + reward stability are hard bounds, the speedup is
        # tolerance-banded only — overlap gains are hardware-dependent).
        # Baselines committed before these sections existed simply have no
        # rows to pair: the hard bounds still gate every fresh row.
        "rollout_async_smoke": ("policy", "max_lag"),
        "rollout_async": ("policy", "max_lag"),
        # quantized-pool RL rollouts (reward trajectory + pool capacity);
        # reward_nondegrading and capacity_ratio >= 1.8 are hard bounds
        "rollout_quant_smoke": ("kv_quant", "group_size"),
        "rollout_quant": ("kv_quant", "group_size"),
        # sampler-policy matrix cells (policy x arch x length-dist,
        # DESIGN.md §Sampler policy registry).  Sparse/quant cells carry NO
        # ``identical`` field (their tokens legitimately diverge from the
        # dense oracle — the correction absorbs the gap), so the identity
        # hard bound only bites where the row opts in; trainer cells'
        # reward_nondegrading and quant cells' capacity_ratio hard-gate,
        # speedups tolerance-band (never floored — these cells trade FLOPs
        # for memory by design)
        "rollout_matrix_smoke": ("policy", "arch", "plen_dist"),
        "rollout_matrix": ("policy", "arch", "plen_dist"),
    },
}
# sections whose rows must meet speedup >= 1.0 regardless of history
HARD_FLOOR_SECTIONS = ("rollout_phase", "rollout_phase_smoke")
# quantized rows (kv_quant other than "none") must report at least this
# effective-capacity multiple over the fp pool at equal block count
QUANT_CAPACITY_FLOOR = 1.8
# trainer rows stamping resilience telemetry (DESIGN.md §Fault tolerance &
# degraded modes) must keep the anomaly guard quiet: a healthy run skips no
# updates, and anything above this fraction means the bench itself trained
# on a poisoned stream.  Rows without the field (baselines committed before
# the telemetry existed) skip the bound.
SKIPPED_UPDATE_FRAC_MAX = 0.05
# phase rows measuring the telemetry=metrics re-run (DESIGN.md
# §Observability & telemetry) must keep instrumentation cheap: min-of-N
# wall-clock with metrics on may cost at most this fraction over min-of-N
# with telemetry off.  Rows without the field (pre-telemetry baselines)
# skip the bound.  Only the rollout_phase sections hard-gate it — they are
# the acceptance target and their decode-dominated cells measure stably;
# the matrix cells stamp the same field informationally, but their slow
# compression-policy runs jitter past 3% on shared CI runners.
TELEMETRY_OVERHEAD_MAX = 0.03
TELEMETRY_GATED_SECTIONS = ("rollout_phase", "rollout_phase_smoke")


def _row_key(row: dict, fields) -> tuple:
    return tuple(row.get(f) for f in fields)


def _provenance(row: dict) -> str:
    """Config provenance of a bench row; rows predating autotuning (no
    ``config_source`` field) ran under the hand-picked defaults."""
    return row.get("config_source") or "default"


def _known_fields(key_fields, committed_rows) -> tuple:
    """Identity fields the committed baseline actually knows about.

    Newly-added row fields (e.g. ``plen_dist``) are absent from baselines
    committed before the field existed; matching on them would orphan every
    fresh row and silently skip the regression check.  Restricting the key
    to fields the old baseline carries keeps those rows paired (and the new
    field starts gating as soon as the baseline is regenerated)."""
    return tuple(f for f in key_fields
                 if any(f in r for r in committed_rows))


def gate_section(name: str, fresh_rows, committed_rows, key_fields,
                 tolerance: float):
    """Pure comparison for one section; returns a list of problem strings."""
    problems = []
    match_fields = _known_fields(key_fields, committed_rows or [])
    # pairing key = (identity fields, config provenance): tuned rows only
    # band-compare against tuned baselines and default rows against default
    # baselines (hard bounds below apply to every fresh row regardless)
    committed_by_key = {(_row_key(r, match_fields), _provenance(r)): r
                        for r in (committed_rows or [])}
    for row in fresh_rows:
        key = (_row_key(row, match_fields), _provenance(row))
        label = f"{name}{[v for v in _row_key(row, key_fields) if v is not None]}"
        if row.get("identical") is False:
            problems.append(f"{label}: outputs not token-identical")
        if row.get("reward_nondegrading") is False:
            problems.append(
                f"{label}: reward degraded over the async smoke horizon "
                f"({row.get('reward_first_half')} -> "
                f"{row.get('reward_second_half')})")
        skipped = row.get("skipped_update_frac")
        if skipped is not None and skipped > SKIPPED_UPDATE_FRAC_MAX:
            problems.append(
                f"{label}: skipped_update_frac {skipped:.3f} > "
                f"{SKIPPED_UPDATE_FRAC_MAX} — the anomaly guard dropped "
                f"updates during the bench run")
        tel_over = row.get("telemetry_overhead_frac")
        if (name in TELEMETRY_GATED_SECTIONS and tel_over is not None
                and tel_over > TELEMETRY_OVERHEAD_MAX):
            problems.append(
                f"{label}: telemetry_overhead_frac {tel_over:.3f} > "
                f"{TELEMETRY_OVERHEAD_MAX} — telemetry=metrics costs more "
                f"than the bounded phase overhead")
        if row.get("kv_quant") not in (None, "none"):
            cap = row.get("capacity_ratio")
            if cap is None:
                problems.append(f"{label}: quantized row has no "
                                f"'capacity_ratio' field")
            elif cap < QUANT_CAPACITY_FLOOR:
                problems.append(
                    f"{label}: capacity_ratio {cap:.2f} < "
                    f"{QUANT_CAPACITY_FLOOR} — quantized pool fails the "
                    f"effective-KV-capacity bound")
        speedup = row.get("speedup")
        if speedup is None:
            problems.append(f"{label}: row has no 'speedup' field")
            continue
        if name in HARD_FLOOR_SECTIONS and speedup < 1.0:
            problems.append(
                f"{label}: speedup {speedup:.2f} < 1.00 — continuous-paged "
                f"rollout phase slower than lockstep")
        base = committed_by_key.get(key)
        if base is not None and "speedup" in base:
            floor = base["speedup"] * (1.0 - tolerance)
            if speedup < floor:
                problems.append(
                    f"{label}: speedup {speedup:.2f} regressed below "
                    f"{floor:.2f} (committed {base['speedup']:.2f} "
                    f"- {tolerance:.0%} tolerance)")
    return problems


def gate(committed_dir: Path, fresh_dir: Path, tolerance: float):
    problems = []
    for fname, sections in GATED_SECTIONS.items():
        fresh_path = fresh_dir / fname
        if not fresh_path.exists():
            problems.append(f"{fname}: missing from fresh results "
                            f"(did the bench run?)")
            continue
        fresh = json.loads(fresh_path.read_text())
        committed_path = committed_dir / fname
        committed = (json.loads(committed_path.read_text())
                     if committed_path.exists() else {})
        for section, key_fields in sections.items():
            if section not in fresh:
                problems.append(f"{fname}:{section}: section missing from "
                                f"fresh results")
                continue
            problems.extend(gate_section(
                section, fresh[section], committed.get(section),
                key_fields, tolerance))
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--committed", required=True, type=Path,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh", required=True, type=Path,
                    help="directory holding the freshly-run BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.35,
                    help="allowed fractional speedup regression vs the "
                         "committed baseline (CI-runner noise band)")
    args = ap.parse_args(argv)
    problems = gate(args.committed, args.fresh, args.tolerance)
    for p in problems:
        print(f"BENCHGATE: {p}")
    if problems:
        print(f"BENCHGATE: {len(problems)} problem(s)")
        return 1
    print("BENCHGATE: all smoke benchmarks within tolerance of the "
          "committed baselines")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
