#!/usr/bin/env python3
"""Markdown link checker for the repo's documentation cross-references.

Checks, with no network access and no third-party deps:

1. Relative links ``[text](path)`` in README.md / DESIGN.md / ROADMAP.md /
   PERFORMANCE.md point at files that exist.
2. Anchor links (``file.md#anchor`` or in-page ``#anchor``) resolve to a
   heading in the target document (GitHub's slug rules: lowercase, strip
   punctuation, spaces -> hyphens).
3. Every ``DESIGN.md §Section`` reference — in the checked docs *and* in
   src/ / tests/ / benchmarks/ docstrings — names a real DESIGN.md section
   (prefix match, so prose may continue after the section name).

Exit code 1 with a per-problem report when anything dangles; used as a CI
step and by tests/test_docs.py so doc refactors can't silently rot links.

  python tools/check_doc_links.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

DOCS = ("README.md", "DESIGN.md", "ROADMAP.md", "PERFORMANCE.md")
CODE_GLOBS = ("src/**/*.py", "tests/*.py", "benchmarks/*.py", "examples/*.py")
LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*$", re.M)
SECTION_REF_RE = re.compile(r"§")


def github_slug(heading: str) -> str:
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)          # drop punctuation (&, :, ...)
    return s.replace(" ", "-")


def headings_of(path: Path):
    return HEADING_RE.findall(path.read_text(encoding="utf-8"))


def check_links(root: Path):
    problems = []
    for name in DOCS:
        doc = root / name
        if not doc.exists():
            problems.append(f"{name}: missing document")
            continue
        text = doc.read_text(encoding="utf-8")
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            dest = doc if not path_part else (doc.parent / path_part)
            if not dest.exists():
                problems.append(f"{name}: broken link -> {target}")
                continue
            if anchor and dest.suffix == ".md":
                slugs = {github_slug(h) for h in headings_of(dest)}
                if anchor not in slugs:
                    problems.append(
                        f"{name}: dangling anchor -> {target} "
                        f"(no heading slugs to '{anchor}' in {dest.name})")
    return problems


def check_design_sections(root: Path):
    """Every `DESIGN.md §...` reference (including `, §...` continuations)
    must prefix-match a DESIGN.md section name.  Bare §-refs to the paper
    (`paper §5.1`) or other docs are not checked."""
    design = root / "DESIGN.md"
    if not design.exists():
        return ["DESIGN.md missing"]
    sections = sorted(
        {h for h in headings_of(design)}, key=len, reverse=True)
    # missing docs are already reported by check_links; don't crash here
    files = [p for p in (root / n for n in DOCS) if p.exists()]
    for pat in CODE_GLOBS:
        files.extend(sorted(root.glob(pat)))
    problems = []
    for f in files:
        text = f.read_text(encoding="utf-8", errors="replace")
        for m in SECTION_REF_RE.finditer(text):
            context = re.sub(r"\s+", " ", text[max(0, m.start() - 70):
                                               m.start()])
            if "DESIGN.md" not in context:
                continue                    # a paper/other-doc § reference
            ref = text[m.end():m.end() + 80]
            # docstring wrapping may break a section name across lines with
            # indentation; collapse runs of whitespace before matching
            ref = re.sub(r"\s+", " ", ref)
            if ref.startswith("<"):
                continue                    # meta-prose placeholder §<...>
            if not any(ref.startswith(s) for s in sections):
                problems.append(
                    f"{f.relative_to(root)}: §-reference does not match any "
                    f"DESIGN.md section: §{ref[:40]!r}")
    return problems


def main(argv=None) -> int:
    root = Path(argv[1] if argv and len(argv) > 1
                else Path(__file__).resolve().parent.parent)
    problems = check_links(root) + check_design_sections(root)
    for p in problems:
        print(f"LINKCHECK: {p}")
    if problems:
        print(f"LINKCHECK: {len(problems)} problem(s)")
        return 1
    print("LINKCHECK: all markdown links and DESIGN.md §-references resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
