#!/usr/bin/env python3
"""Offline analyzer for telemetry traces (stdlib only, no jax import).

Reads a Chrome-trace-event JSON file produced by ``--telemetry trace``
(``repro.launch.train`` / ``repro.launch.serve`` / the rollout bench) and
prints:

1. **Phase-time breakdown** — wall-clock attributed to the Sparse-RL
   phases (prefill / decode / harvest / update / other / bubble) from the
   leaf spans, as a fraction of the container spans' wall-clock
   (``train_step`` for training traces, ``serve_run`` for serving).
   Bubble is the unattributed remainder: host bookkeeping between
   instrumented sections.  In an async-pipeline trace the producer thread
   overlaps the learner, so rollout categories can legitimately exceed
   100% of learner wall — the breakdown is per-trace arithmetic, not a
   utilization claim (DESIGN.md §Observability & telemetry).
2. **Top-N slowest spans** — the individual events worth opening in
   Perfetto (ui.perfetto.dev) first.
3. **Mismatch health** — the Sparse-RL stability diagnostics embedded in
   ``otherData.metrics``: the per-token log-xi histogram, rejection / veto
   rates, mean_rho and staleness KL (paper Eqs. 5-7), plus resilience
   counters.
4. **Run-log summary** — warn/error events from ``reports/run_log.jsonl``
   when ``--run-log`` is given.

``--check`` turns the breakdown into a CI assertion: the categorized
fraction must come within ``--max-bubble`` of 100% of container
wall-clock (exit 1 otherwise) — the pin that the instrumentation actually
covers the hot paths instead of decorating a few of them.

  PYTHONPATH=src python -m repro.launch.train --smoke --steps 2 \
      --telemetry trace --trace-out reports/trace_train.json
  python tools/trace_report.py reports/trace_train.json \
      --run-log reports/run_log.jsonl --check --max-bubble 0.10
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter
from pathlib import Path

#: leaf span -> breakdown category.  Container spans (train_step,
#: serve_run, rollout_phase) and nested-inside-a-leaf spans
#: (prefill_dispatch lives inside admit_sweep) are deliberately absent —
#: counting them would double-book the same wall-clock.
CATEGORY_OF = {
    "admit_sweep": "prefill",     # admission + batched prefill dispatch
    "phase_setup": "prefill",     # begin_phase cache alloc + request build
    "decode_chunk": "decode",     # chunked decode dispatch
    "harvest": "harvest",         # device->host fetch + completion plumbing
    "collate": "harvest",         # completions -> trainer rollout batch
    "rescore": "update",          # dense pi_old / pi_ref rescores
    "storm_guard": "update",      # veto-rate scan (full logp device_get)
    "advantages": "update",       # group-relative advantage reduction
    "update": "update",           # minibatched Sparse-RL updates
    "verify": "update",           # reward verification
    "checkpoint": "update",       # checkpoint save
    "phase_inputs": "other",      # prompt encoding / phase RNG
    "metrics_publish": "other",   # metric assembly (full-plane device_get)
}
CATEGORIES = ("prefill", "decode", "harvest", "update", "other")
#: spans whose duration IS the denominator (first name found wins)
CONTAINER_SPANS = ("train_step", "serve_run")


def load_trace(path: Path) -> dict:
    doc = json.loads(path.read_text())
    if "traceEvents" not in doc:
        raise SystemExit(f"{path}: not a Chrome trace (no 'traceEvents')")
    return doc


def complete_events(doc: dict):
    return [e for e in doc["traceEvents"] if e.get("ph") == "X"]


def breakdown(events) -> dict:
    """Category -> seconds, plus ``wall`` (container span sum) and
    ``bubble`` (wall minus categorized time; negative = overlap)."""
    container = next((n for n in CONTAINER_SPANS
                      if any(e["name"] == n for e in events)), None)
    if container is not None:
        wall = sum(e["dur"] for e in events if e["name"] == container)
        steps = sum(1 for e in events if e["name"] == container)
    else:  # no container span: fall back to the trace's own extent
        wall = (max(e["ts"] + e["dur"] for e in events)
                - min(e["ts"] for e in events)) if events else 0.0
        steps = 0
    cat = dict.fromkeys(CATEGORIES, 0.0)
    for e in events:
        c = CATEGORY_OF.get(e["name"])
        if c is not None:
            cat[c] += e["dur"]
    out = {k: v / 1e6 for k, v in cat.items()}          # us -> s
    out["wall"] = wall / 1e6
    out["bubble"] = (wall - sum(cat.values())) / 1e6
    out["container"] = container or "(trace extent)"
    out["steps"] = steps
    return out


def print_breakdown(bd: dict) -> None:
    wall = bd["wall"]
    print(f"== phase breakdown over {bd['container']} "
          f"({bd['steps'] or '?'} spans, wall {wall:.3f}s) ==")
    if wall <= 0:
        print("  (no container wall-clock recorded)")
        return
    for c in (*CATEGORIES, "bubble"):
        print(f"  {c:<8} {bd[c]:>9.3f}s  {bd[c] / wall:>6.1%}")
    covered = sum(bd[c] for c in CATEGORIES)
    print(f"  {'total':<8} {covered:>9.3f}s  {covered / wall:>6.1%} "
          f"categorized")


def print_slowest(events, n: int) -> None:
    print(f"== top {n} slowest spans ==")
    for e in sorted(events, key=lambda e: -e["dur"])[:n]:
        args = e.get("args") or {}
        brief = " ".join(f"{k}={v}" for k, v in list(args.items())[:4])
        print(f"  {e['dur'] / 1e3:>10.2f} ms  {e['name']:<18} "
              f"tid={e['tid']}" + (f"  {brief}" if brief else ""))


def _hist_line(name: str, snap: dict) -> str:
    if "p50" in snap:
        return (f"  {name:<26} n={snap['count']:<7} mean={snap['mean']:.4g} "
                f"p50={snap['p50']:.4g} p90={snap['p90']:.4g} "
                f"p99={snap['p99']:.4g}")
    return f"  {name:<26} {snap}"


def print_mismatch_health(metrics: dict) -> None:
    """The Sparse-RL stability panel: is the sparse behaviour policy still
    close enough to the dense learner for the correction to hold?"""
    groups = (("mismatch.", "== mismatch health (paper Eqs. 5-7) =="),
              ("train.", "== training signal =="),
              ("resilience.", "== resilience counters =="),
              ("engine.", "== engine =="))
    for prefix, header in groups:
        rows = {k: v for k, v in metrics.items() if k.startswith(prefix)}
        if not rows:
            continue
        print(header)
        for name, snap in sorted(rows.items()):
            if set(snap) == {"value"}:
                print(f"  {name:<26} {snap['value']:.6g}")
            else:
                print(_hist_line(name, snap))


def print_run_log(path: Path) -> None:
    levels: Counter = Counter()
    events: Counter = Counter()
    noisy = []
    with path.open() as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            levels[rec.get("level", "info")] += 1
            events[rec.get("event", "?")] += 1
            if rec.get("level") in ("warn", "error"):
                noisy.append(rec)
    print(f"== run log {path} ==")
    print("  levels: " + " ".join(f"{k}={v}" for k, v in sorted(levels.items())))
    top = ", ".join(f"{k}x{v}" for k, v in events.most_common(6))
    print(f"  events: {top}")
    for rec in noisy[:10]:
        print(f"  {rec['level'].upper()} {rec['event']}: "
              f"{rec.get('msg', '')}")
    if len(noisy) > 10:
        print(f"  ... {len(noisy) - 10} more warn/error events")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", type=Path,
                    help="Chrome trace JSON from --telemetry trace")
    ap.add_argument("--run-log", type=Path, default=None,
                    help="reports/run_log.jsonl to summarize alongside")
    ap.add_argument("--top", type=int, default=10,
                    help="slowest spans to list")
    ap.add_argument("--check", action="store_true",
                    help="CI mode: fail unless the categorized breakdown "
                         "covers wall-clock to within --max-bubble")
    ap.add_argument("--max-bubble", type=float, default=0.10,
                    help="check mode: max |1 - categorized/wall| fraction")
    args = ap.parse_args(argv)

    doc = load_trace(args.trace)
    events = complete_events(doc)
    if not events:
        print(f"{args.trace}: no complete ('X') span events")
        return 1 if args.check else 0

    bd = breakdown(events)
    print_breakdown(bd)
    print()
    print_slowest(events, args.top)
    metrics = (doc.get("otherData") or {}).get("metrics") or {}
    if metrics:
        print()
        print_mismatch_health(metrics)
    dropped = (doc.get("otherData") or {}).get("dropped_events")
    if dropped:
        print(f"\nWARNING: tracer dropped {dropped} events (buffer full) — "
              f"the breakdown undercounts")
    if args.run_log and args.run_log.exists():
        print()
        print_run_log(args.run_log)

    if args.check:
        if bd["wall"] <= 0:
            print("\nTRACECHECK: no container wall-clock — nothing to check")
            return 1
        covered = sum(bd[c] for c in CATEGORIES)
        gap = 1.0 - covered / bd["wall"]
        ok = abs(gap) <= args.max_bubble
        print(f"\nTRACECHECK: categorized {covered / bd['wall']:.1%} of "
              f"wall (gap {gap:+.1%}, bound ±{args.max_bubble:.0%}): "
              f"{'OK' if ok else 'FAIL'}")
        if dropped:
            print("TRACECHECK: FAIL — dropped events invalidate the "
                  "breakdown")
            return 1
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
